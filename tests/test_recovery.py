"""Crash-safe serving (DESIGN.md §13): durable request journal, engine
snapshot/restore of the quantized slot cache, integrity-validated
artifact loading, and crash chaos + recovery.

The load-bearing property is the END-TO-END one: a seeded workload is
crashed at a random step boundary (the injected-crash fault), a FRESH
engine recovers from the snapshot + journal, and every request that had
not retired completes with tokens bit-identical to an uncrashed
reference run — across fp / int8-dynamic / int8-static KV caches.
Exactly-once retirement and an empty slot pool after replay come with
it, and a snapshot with a single flipped byte must be rejected by the
integrity validator, never served.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import (Engine, EngineConfig, FaultSpec, InjectedCrash,
                          IntegrityError, RequestJournal, compact_journal,
                          occupied_slots, read_snapshot)
from repro.engine.kvcache import CACHE_DATA_FIELDS
from repro.engine.recovery import (array_checksum, check_code_range,
                                   check_finite, check_positive,
                                   checksum_arrays, load_journal,
                                   replay_journal, validate_cache_arrays,
                                   verify_checksums)
from repro.models import get_model
from repro.obs.schema import validate_events

sys.path.append(os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks"))

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48
BUDGETS = [6, 1, 6, 4, 3, 6, 5]

#: (kv_mode, use static scales) — the three cache configurations every
#: crash/recovery property must hold under
KV_MODES = [("fp", False), ("int8", False), ("int8", True)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(7)]
    return cfg, model, params, prompts


@pytest.fixture(scope="module")
def kv_scales(setup):
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, MAX_LEN))
             for _ in range(4)]
    return kv_static_scales(collect_kv_stats(cfg, params, calib,
                                             qchunks=4))


def mk_ecfg(**kw):
    base = dict(n_slots=3, max_len=MAX_LEN, prefill_bucket=8,
                prefill_chunk=8)
    base.update(kw)
    return EngineConfig(**base)


def submit_all(eng, prompts):
    for p, b in zip(prompts, BUDGETS):
        eng.submit(p, max_new_tokens=b)


# ================================================================ journal
def test_journal_records_full_lifecycle(setup, tmp_path):
    """Every request leaves submit/admit/first_token/retire records; the
    journal is a valid trace file (header + schema'd events) and the
    retire records carry the full output token list."""
    cfg, model, params, prompts = setup
    jpath = str(tmp_path / "journal.jsonl")
    eng = Engine(cfg, params, mk_ecfg(journal_path=jpath))
    submit_all(eng, prompts)
    fin = {r.uid: list(r.out) for r in eng.drain()}
    records = load_journal(jpath)
    assert validate_events(records) == []
    with open(jpath) as f:
        header = json.loads(f.readline())
    assert header["kind"] == "header" and header["journal"] is True
    by_name = {}
    for rec in records:
        if rec.get("kind") == "event" and rec.get("uid") is not None:
            by_name.setdefault(rec["name"], set()).add(rec["uid"])
    for name in ("submit", "admit", "first_token", "retire"):
        assert by_name[name] == set(fin), name
    submitted, retired = replay_journal(records)
    assert sorted(submitted) == sorted(fin)
    for uid, out in fin.items():
        assert retired[uid]["out"] == out
        assert retired[uid]["n_out"] == len(out)
        assert submitted[uid]["prompt"] == [int(t) for t in
                                            prompts[uid]]
        assert submitted[uid]["budget"] == BUDGETS[uid]


def test_journal_compaction(setup, tmp_path):
    """Compaction drops records made redundant by a retire but preserves
    replay semantics exactly: same (submitted-unretired, retired) maps,
    same retire payloads, still a single-header valid trace. A second
    pass is a no-op (already compact)."""
    cfg, model, params, prompts = setup
    jpath = str(tmp_path / "journal.jsonl")
    eng = Engine(cfg, params, mk_ecfg(journal_path=jpath))
    submit_all(eng, prompts)
    eng.drain()
    before = load_journal(jpath)
    _, retired_before = replay_journal(before)
    n_before, n_after = compact_journal(jpath)
    assert n_before == len(before) and n_after < n_before
    after = load_journal(jpath)
    assert validate_events(after) == []
    assert len(after) == n_after
    _, retired_after = replay_journal(after)
    assert retired_after == retired_before
    # every retired uid kept exactly its retire record
    per_uid = {}
    for rec in after:
        if rec.get("kind") == "event" and rec.get("uid") is not None:
            per_uid.setdefault(rec["uid"], []).append(rec["name"])
    for uid in retired_before:
        assert per_uid[uid] == ["retire"]
    assert compact_journal(jpath) == (n_after, n_after)


def test_journal_resume_single_header(tmp_path):
    """Reopening with resume=True appends without a second header — the
    merged crash+recovery journal stays one valid trace."""
    jpath = str(tmp_path / "j.jsonl")
    j1 = RequestJournal(jpath, meta={"arch": "t"})
    j1.event("submit", uid=0, prompt=[1], budget=1, cls="interactive",
             ttft_deadline_s=None, deadline_s=None)
    j1.close()
    j2 = RequestJournal(jpath, resume=True)
    j2.event("retire", uid=0, slot=0, reason="budget", n_out=1, out=[5])
    j2.close()
    records = load_journal(jpath)
    assert validate_events(records) == []
    with open(jpath) as f:
        headers = [ln for ln in f if '"header"' in ln]
    assert len(headers) == 1
    submitted, retired = replay_journal(records)
    assert list(submitted) == [0] and retired[0]["out"] == [5]
    # resume=False (a genuinely new run) truncates
    j3 = RequestJournal(jpath, resume=False)
    j3.close()
    assert replay_journal(load_journal(jpath)) == ({}, {})


# ============================================================== integrity
def test_checksum_primitives():
    a = np.arange(12, dtype=np.int8).reshape(3, 4)
    cs = checksum_arrays({"x": a})
    assert cs["x"].startswith("crc32:")
    verify_checksums({"x": a.copy()}, cs)
    # same bytes, different shape/dtype must NOT collide
    assert array_checksum(a) != array_checksum(a.reshape(4, 3))
    assert array_checksum(a) != array_checksum(a.view(np.uint8))
    b = a.copy()
    b[1, 2] ^= 1
    with pytest.raises(IntegrityError) as ei:
        verify_checksums({"x": b}, cs)
    assert ei.value.reason == "checksum"
    with pytest.raises(IntegrityError) as ei:
        verify_checksums({}, cs)
    assert ei.value.reason == "missing_array"


def test_invariant_validators():
    with pytest.raises(IntegrityError) as ei:
        check_finite("s", np.array([1.0, np.nan]))
    assert ei.value.reason == "nonfinite"
    with pytest.raises(IntegrityError) as ei:
        check_positive("s", np.array([0.5, 0.0]))
    assert ei.value.reason == "nonpositive_scale"
    check_code_range("q", np.array([-128, 127], np.int16), 8)
    with pytest.raises(IntegrityError) as ei:
        check_code_range("q", np.array([-3, 4], np.int16), 3)
    assert ei.value.reason == "code_range"
    # kv_pos must be -1 or its own index
    pos = np.full((1, 2, 4), -1, np.int32)
    pos[0, 0, :2] = [0, 1]
    validate_cache_arrays({"cache/kv_pos": pos}, "fp")
    pos[0, 1, 3] = 1
    with pytest.raises(IntegrityError) as ei:
        validate_cache_arrays({"cache/kv_pos": pos}, "fp")
    assert ei.value.reason == "kv_pos_invalid"


def _tamper_npz(path, key, mutate):
    """Load an npz, apply `mutate` to arrays[key], rewrite in place."""
    data = dict(np.load(path))
    data[key] = mutate(data[key])
    np.savez(path, **data)


def _retamper_manifest_checksums(snap_dir):
    """Recompute manifest checksums after a tamper — for testing the
    SEMANTIC invariants behind a checksum that 'passes'."""
    with np.load(os.path.join(snap_dir, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    mpath = os.path.join(snap_dir, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["checksums"] = checksum_arrays(arrays)
    with open(mpath, "w") as f:
        json.dump(manifest, f)


@pytest.fixture()
def snapshotted(setup, tmp_path):
    """An int8 engine mid-run with a written snapshot (shared by the
    corruption tests; function-scoped — each test tampers its own copy)."""
    cfg, model, params, prompts = setup
    eng = Engine(cfg, params, mk_ecfg(kv_mode="int8"))
    submit_all(eng, prompts)
    for _ in range(3):
        eng.step()
    spath = str(tmp_path / "snap")
    eng.snapshot(spath)
    return cfg, params, eng, spath


def test_snapshot_flipped_byte_rejected(snapshotted):
    cfg, params, eng, spath = snapshotted
    npz = os.path.join(spath, "arrays.npz")
    _tamper_npz(npz, "cache/k", lambda a: a ^ np.int8(1))
    with pytest.raises(IntegrityError) as ei:
        read_snapshot(spath)
    assert ei.value.reason == "checksum"


def test_snapshot_semantic_invariants_rejected(snapshotted):
    """Even with a 'valid' checksum (recomputed post-tamper), broken
    cache invariants — out-of-place kv_pos, nonpositive scale — fail."""
    cfg, params, eng, spath = snapshotted

    def bad_pos(a):
        a = a.copy()
        a[0, 0, -1] = 1          # occupied claim at the wrong index
        return a
    _tamper_npz(os.path.join(spath, "arrays.npz"), "cache/kv_pos",
                bad_pos)
    _retamper_manifest_checksums(spath)
    with pytest.raises(IntegrityError) as ei:
        read_snapshot(spath)
    assert ei.value.reason == "kv_pos_invalid"


def test_snapshot_nonpositive_scale_rejected(snapshotted):
    cfg, params, eng, spath = snapshotted

    def bad_scale(a):
        a = a.copy()
        a.reshape(-1)[0] = 0.0
        return a
    _tamper_npz(os.path.join(spath, "arrays.npz"), "cache/k_scale",
                bad_scale)
    _retamper_manifest_checksums(spath)
    with pytest.raises(IntegrityError) as ei:
        read_snapshot(spath)
    assert ei.value.reason == "nonpositive_scale"


def test_snapshot_schema_and_geometry_mismatch(snapshotted):
    cfg, params, eng, spath = snapshotted
    # wrong engine geometry: loud config_mismatch, names the diff
    other = Engine(cfg, params, mk_ecfg(n_slots=2, kv_mode="int8"))
    with pytest.raises(IntegrityError) as ei:
        other.restore(spath)
    assert ei.value.reason == "config_mismatch"
    # wrong kv mode too (fingerprint covers cache.mode)
    fp_eng = Engine(cfg, params, mk_ecfg(kv_mode="fp"))
    with pytest.raises(IntegrityError) as ei:
        fp_eng.restore(spath)
    assert ei.value.reason == "config_mismatch"
    # schema bump: refused before any array is touched
    mpath = os.path.join(spath, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["schema"] = 99
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IntegrityError) as ei:
        read_snapshot(spath)
    assert ei.value.reason == "schema"
    with pytest.raises(IntegrityError) as ei:
        read_snapshot(str(spath) + "_nonexistent")
    assert ei.value.reason == "schema"


def test_ckpt_checksums_roundtrip_and_corruption(setup, tmp_path):
    """checkpoint/ckpt.py shares the validator: a saved quantized tree
    restores clean, a flipped byte raises, and a corrupt quantized code
    range raises even when checksums are recomputed."""
    from repro.checkpoint import ckpt
    from repro.core import QuantConfig, QuantPolicy, quantize_tree
    cfg, model, params, prompts = setup
    qtree, _ = quantize_tree(KEY, params,
                             QuantPolicy(cfg=QuantConfig(bits=2)))
    cdir = str(tmp_path / "ckpt")
    ckpt.save(cdir, 0, qtree)
    restored, step = ckpt.restore(cdir, params)
    assert step == 0
    npz = os.path.join(cdir, "step_00000000", "arrays.npz")
    data = dict(np.load(npz))
    qkey = next(k for k in data if k.endswith(".q"))
    data[qkey] = data[qkey] ^ np.int8(1)
    np.savez(npz, **data)
    with pytest.raises(IntegrityError) as ei:
        ckpt.restore(cdir, params)
    assert ei.value.reason == "checksum"
    # re-stamp checksums: the INT2 code range check still trips
    mpath = os.path.join(cdir, "step_00000000", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    data[qkey] = np.full_like(data[qkey], 100)
    np.savez(npz, **data)
    manifest["checksums"] = checksum_arrays(data)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IntegrityError) as ei:
        ckpt.restore(cdir, params)
    assert ei.value.reason == "code_range"


def test_recipe_validation(setup, kv_scales, tmp_path):
    """QuantRecipe.load shares the validator: checksummed round-trip,
    corrupt scales rejected, nonpositive KV scale rejected even when
    the checksum 'passes' (recorded over the bad array at save)."""
    from repro.calib import QuantRecipe
    cfg, model, params, prompts = setup
    rdir = str(tmp_path / "rec")
    QuantRecipe(name="r", arch=cfg.name, kv_scales=kv_scales,
                kv_qchunks=4).save(rdir)
    rec = QuantRecipe.load(rdir)
    np.testing.assert_array_equal(rec.kv_scales["k_scale"],
                                  np.asarray(kv_scales["k_scale"],
                                             np.float32))
    _tamper_npz(os.path.join(rdir, "scales.npz"), "kv/k_scale",
                lambda a: a + 1.0)
    with pytest.raises(IntegrityError) as ei:
        QuantRecipe.load(rdir)
    assert ei.value.reason == "checksum"
    bad = {k: np.asarray(v).copy() for k, v in kv_scales.items()}
    bad["v_scale"].reshape(-1)[0] = -1.0
    rdir2 = str(tmp_path / "rec2")
    QuantRecipe(name="r", arch=cfg.name, kv_scales=bad,
                kv_qchunks=4).save(rdir2)
    with pytest.raises(IntegrityError) as ei:
        QuantRecipe.load(rdir2)
    assert ei.value.reason == "nonpositive_scale"


# ================================================== snapshot round-trip
def _assert_engine_state_equal(x, y):
    for name in CACHE_DATA_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(x.cache, name)),
            np.asarray(getattr(y.cache, name)), err_msg=name)
    np.testing.assert_array_equal(x._last_tok, y._last_tok)
    np.testing.assert_array_equal(x._pos, y._pos)
    np.testing.assert_array_equal(x._prefill_prog, y._prefill_prog)
    assert [r and r.uid for r in x.sched.slots] \
        == [r and r.uid for r in y.sched.slots]
    assert [r.uid for r in x.sched.queue] \
        == [r.uid for r in y.sched.queue]


def _roundtrip_check(setup, scales, kv_mode, spath, n_steps):
    """Snapshot at step `n_steps` (random occupancy, slots mid-prefill,
    possibly some requests already retired), restore into a FRESH
    engine: (a) every cache array + host decode state bit-identical,
    (b) one further engine step stays bit-identical on both sides."""
    cfg, model, params, prompts = setup
    a = Engine(cfg, params, mk_ecfg(kv_mode=kv_mode), kv_scales=scales)
    submit_all(a, prompts)
    for _ in range(n_steps):
        if a.sched.idle:
            break
        a.step()
    a.snapshot(spath)
    b = Engine(cfg, params, mk_ecfg(kv_mode=kv_mode), kv_scales=scales)
    b.restore(spath)
    _assert_engine_state_equal(a, b)
    if not a.sched.idle:
        # pre-snapshot retires are journal state, not snapshot state —
        # only the finishes PRODUCED by the next step must agree
        na, nb = len(a.sched.finished), len(b.sched.finished)
        a.step()
        b.step()
        _assert_engine_state_equal(a, b)
        assert [(r.uid, r.out) for r in a.sched.finished[na:]] \
            == [(r.uid, r.out) for r in b.sched.finished[nb:]]


@pytest.mark.parametrize("kv_mode,static", KV_MODES,
                         ids=["fp", "int8", "int8-static"])
@pytest.mark.parametrize("n_steps", [0, 2, 6])
def test_snapshot_restore_roundtrip(setup, kv_scales, tmp_path,
                                    kv_mode, static, n_steps):
    """Deterministic spine of the round-trip property: step counts that
    land mid-prefill (0, 2) and mid-decode-with-retires (6), across all
    three KV cache configurations. Runs everywhere; the hypothesis
    variant below widens the step-count coverage when available."""
    _roundtrip_check(setup, kv_scales if static else None, kv_mode,
                     str(tmp_path / "snap"), n_steps)


@pytest.mark.parametrize("kv_mode,static", KV_MODES,
                         ids=["fp", "int8", "int8-static"])
def test_snapshot_restore_roundtrip_property(setup, kv_scales, tmp_path,
                                             kv_mode, static):
    """Hypothesis widening of the round-trip property: random snapshot
    step (random occupancy / mid-prefill slots / retired mixes)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    scales = kv_scales if static else None
    counter = [0]

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, 9))
    def prop(n_steps):
        counter[0] += 1
        _roundtrip_check(setup, scales, kv_mode,
                         str(tmp_path / f"snap_{counter[0]}"), n_steps)
    prop()


# ================================================ end-to-end crash chaos
def test_crash_fault_spec_parse():
    s = FaultSpec.parse("crash=0.25,crash_kill=1,seed=2,max=1")
    assert s.crash_rate == 0.25 and s.crash_kill is True
    assert s.seed == 2 and s.max_faults == 1
    s2 = FaultSpec.parse("crash=0.1")
    assert s2.crash_kill is False


def test_crash_draw_preserves_other_streams(setup):
    """crash_rate=0 must consume NO rng draws: adding the crash fault
    class cannot perturb the seeded streams of existing chaos specs."""
    from repro.engine import FaultInjector
    a = FaultInjector(FaultSpec(seed=5, step_exception_rate=0.3,
                                max_faults=100))
    b = FaultInjector(FaultSpec(seed=5, step_exception_rate=0.3,
                                max_faults=100, crash_rate=0.0))
    draws_a = [a.draw_step() for _ in range(20)]
    draws_b = []
    for _ in range(20):
        assert b.draw_crash() is False      # rate 0: no rng consumed
        draws_b.append(b.draw_step())
    assert draws_a == draws_b


@pytest.mark.parametrize("kv_mode,static", KV_MODES,
                         ids=["fp", "int8", "int8-static"])
def test_crash_recovery_token_identity(setup, kv_scales, tmp_path,
                                       kv_mode, static):
    """THE acceptance property: seeded crash at a step boundary, fresh-
    process recovery from snapshot + journal, and every surviving
    request completes token-identical to an uncrashed reference —
    exactly-once retirement, no slot-pool leak, journal still a valid
    trace, recovery counters exported."""
    cfg, model, params, prompts = setup
    scales = kv_scales if static else None
    jpath = str(tmp_path / "journal.jsonl")
    spath = str(tmp_path / "snap")

    ref = Engine(cfg, params, mk_ecfg(kv_mode=kv_mode), kv_scales=scales)
    submit_all(ref, prompts)
    ref_out = {r.uid: list(r.out) for r in ref.drain()}

    crashed_cfg = mk_ecfg(kv_mode=kv_mode, journal_path=jpath,
                          snapshot_path=spath, snapshot_every=3,
                          fault_spec=FaultSpec(seed=2, crash_rate=0.25,
                                               max_faults=1))
    eng = Engine(cfg, params, crashed_cfg, kv_scales=scales)
    submit_all(eng, prompts)
    with pytest.raises(InjectedCrash):
        eng.drain()
    # the crash fired at a step boundary AFTER the journal sync: the
    # journal's durable horizon covers everything the engine did
    del eng

    eng2 = Engine(cfg, params, mk_ecfg(kv_mode=kv_mode,
                                       journal_path=jpath,
                                       journal_resume=True,
                                       snapshot_path=spath),
                  kv_scales=scales)
    info = eng2.recover(spath, jpath)
    fin2 = {r.uid: list(r.out) for r in eng2.drain()}

    # exactly-once: journal-retired uids and post-recovery finishes
    # partition the workload
    done = {uid: rec["out"] for uid, rec in info["retired"].items()}
    for uid, out in fin2.items():
        assert uid not in done, f"uid {uid} retired twice"
        done[uid] = out
    assert sorted(done) == list(range(len(prompts)))
    assert occupied_slots(eng2.cache) == []
    assert not any(eng2.sched.slots) and not eng2.sched.queue

    # zero token divergence for every survivor (and pre-crash retires)
    for uid, out in ref_out.items():
        assert done[uid] == out, f"uid {uid} diverged after recovery"

    # merged crash+recovery journal stays a valid trace
    records = load_journal(jpath)
    assert validate_events(records) == []
    names = {r.get("name") for r in records if r.get("kind") == "event"}
    assert {"snapshot", "restore"} <= names

    # recovery counters on the exported scrape surface
    prom = eng2.registry.to_prometheus()
    for name in ("repro_engine_snapshots_total",
                 "repro_engine_restore_total",
                 "repro_engine_journal_replayed_requests_total",
                 "repro_engine_restore_duration_s_bucket"):
        assert name in prom, name
    snap = eng2.registry.snapshot()
    assert snap["engine_restore"] == 1
    assert snap["engine_journal_replayed_requests"] \
        == info["n_restored"] + info["n_requeued"]


def test_journal_only_recovery(setup, tmp_path):
    """No snapshot at all (crash before the first one): every un-retired
    request re-prefills from its journal submit record and still matches
    the reference bit-for-bit."""
    cfg, model, params, prompts = setup
    jpath = str(tmp_path / "journal.jsonl")
    ref = Engine(cfg, params, mk_ecfg())
    submit_all(ref, prompts)
    ref_out = {r.uid: list(r.out) for r in ref.drain()}

    eng = Engine(cfg, params, mk_ecfg(journal_path=jpath))
    submit_all(eng, prompts)
    for _ in range(4):
        eng.step()
    pre_retired = {r.uid: list(r.out) for r in eng.sched.finished}
    del eng                                 # "crash" with no snapshot

    eng2 = Engine(cfg, params, mk_ecfg(journal_path=jpath,
                                       journal_resume=True))
    info = eng2.recover(None, jpath)
    assert info["manifest"] is None
    assert info["n_restored"] == 0
    assert info["n_requeued"] == len(prompts) - len(pre_retired)
    assert {int(u) for u in info["retired"]} == set(pre_retired)
    fin2 = {r.uid: list(r.out) for r in eng2.drain()}
    done = {uid: rec["out"] for uid, rec in info["retired"].items()}
    done.update(fin2)
    assert done == {uid: out for uid, out in ref_out.items()}


def test_restore_duration_histogram_buckets():
    from repro.obs.metrics import RESTORE_BUCKETS_S
    assert list(RESTORE_BUCKETS_S) == sorted(RESTORE_BUCKETS_S)
    assert RESTORE_BUCKETS_S[0] <= 1e-3 and RESTORE_BUCKETS_S[-1] >= 60
