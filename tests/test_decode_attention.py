"""Fused decode-attention kernel vs the materialize-then-`attend` oracle:
fp / int8-dynamic / int8-static caches, empty slots, ragged kv_pos, GQA
(Hq > Hkv), both lowerings (Pallas interpret mode and the jnp chunk
sweep), plus engine-level greedy equivalence and the mid-flight
static-scale hot-swap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import Engine, EngineConfig
from repro.engine.kvcache import (dequantize_kv, fused_slot_attention,
                                  hotswap_static_scales, init_slot_cache,
                                  materialize_layer, quantize_kv,
                                  quantize_kv_static, slot_layer_update,
                                  slot_layer_write)
from repro.kernels.decode_attention import decode_attention
from repro.models import get_model
from repro.models.attention import attend

KEY = jax.random.PRNGKey(0)


def make_case(seed, N=3, T=48, Hq=8, Hkv=4, D=32, C=4, lens=None):
    """Random K/V + ragged slot occupancy. lens[i] = valid prefix length
    of slot i (0 = empty slot); q_pos is the last valid position."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(N, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(N, T, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(N, T, Hkv, D)).astype(np.float32))
    if lens is None:
        lens = [int(x) for x in rng.integers(0, T + 1, size=N)]
    kv_pos = np.full((N, T), -1, np.int32)
    for i, n in enumerate(lens):
        kv_pos[i, :n] = np.arange(n)
    q_pos = np.asarray([max(n - 1, 0) for n in lens], np.int32)
    return q, k, v, jnp.asarray(kv_pos), jnp.asarray(q_pos), lens


def reference(q, k, v, kv_pos, q_pos):
    """The legacy read path: dense `attend` over a materialized cache."""
    return attend(q[:, None], k, v, q_pos[:, None], kv_pos)[:, 0]


def check(out, ref, lens, atol=2e-5):
    """Occupied slots must match the oracle; empty slots are exact 0 in
    the fused path (the oracle emits a meaningless mean-V row there)."""
    out, ref = np.asarray(out), np.asarray(ref)
    for i, n in enumerate(lens):
        if n > 0:
            np.testing.assert_allclose(out[i], ref[i], atol=atol,
                                       err_msg=f"slot {i} len {n}")
        else:
            assert np.all(out[i] == 0.0), f"empty slot {i} not zeroed"


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
@pytest.mark.parametrize("kv_chunk", [None, 16])
def test_fp_parity_ragged(use_pallas, kv_chunk):
    q, k, v, kv_pos, q_pos, lens = make_case(0, lens=[48, 7, 0])
    ref = reference(q, k, v, kv_pos, q_pos)
    out = decode_attention(q, k, v, kv_pos, q_pos, mode="fp",
                           kv_chunk=kv_chunk, use_pallas=use_pallas,
                           interpret=use_pallas)
    check(out, ref, lens)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
def test_int8_dynamic_parity(use_pallas):
    q, k, v, kv_pos, q_pos, lens = make_case(1, lens=[21, 48, 3])
    qk, ks, kz = quantize_kv(k, 4)
    qv, vs, vz = quantize_kv(v, 4)
    ref = reference(q, dequantize_kv(qk, ks, kz), dequantize_kv(qv, vs, vz),
                    kv_pos, q_pos)
    out = decode_attention(q, qk, qv, kv_pos, q_pos, k_scale=ks, k_zero=kz,
                           v_scale=vs, v_zero=vz, mode="int8", kv_chunk=16,
                           use_pallas=use_pallas, interpret=use_pallas)
    check(out, ref, lens, atol=1e-4)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
def test_int8_static_parity(use_pallas):
    q, k, v, kv_pos, q_pos, lens = make_case(2, lens=[10, 0, 30])
    Hkv, C = k.shape[2], 4
    rng = np.random.default_rng(9)
    ss = jnp.asarray(1.0 + rng.uniform(size=(1, 1, Hkv, C)).astype(np.float32))
    zz = jnp.asarray(rng.normal(size=(1, 1, Hkv, C)).astype(np.float32))
    qk = quantize_kv_static(k, ss, zz)
    qv = quantize_kv_static(v, ss, zz)
    ref = reference(q, dequantize_kv(qk, ss, zz), dequantize_kv(qv, ss, zz),
                    kv_pos, q_pos)
    out = decode_attention(q, qk, qv, kv_pos, q_pos, k_scale=ss, k_zero=zz,
                           v_scale=ss, v_zero=zz, mode="int8",
                           per_entry_scales=False, kv_chunk=16,
                           use_pallas=use_pallas, interpret=use_pallas)
    check(out, ref, lens, atol=1e-4)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
def test_gqa_groups(use_pallas):
    """Hq > Hkv: grouped accumulation must equal the broadcast-to-Hq
    oracle."""
    q, k, v, kv_pos, q_pos, lens = make_case(3, Hq=8, Hkv=2, lens=[16, 48, 5])
    ref = reference(q, k, v, kv_pos, q_pos)
    out = decode_attention(q, k, v, kv_pos, q_pos, mode="fp", kv_chunk=16,
                           use_pallas=use_pallas, interpret=use_pallas)
    check(out, ref, lens)


def test_dead_chunk_skip_matches_full_sweep():
    """Chunks with no valid entry are skipped (cond / pl.when) — results
    must be identical to a single-chunk sweep that computes everything."""
    q, k, v, kv_pos, q_pos, lens = make_case(4, T=64, lens=[9, 12, 5])
    full = decode_attention(q, k, v, kv_pos, q_pos, mode="fp",
                            kv_chunk=64, use_pallas=False)
    skip = decode_attention(q, k, v, kv_pos, q_pos, mode="fp",
                            kv_chunk=8, use_pallas=False)
    np.testing.assert_allclose(np.asarray(full), np.asarray(skip), atol=2e-5)


def test_slot_cache_roundtrip_fused_vs_legacy():
    """`slot_layer_write` + `fused_slot_attention` == `slot_layer_update`
    + `attend` on a live per-layer slice (the two read paths the
    attention dispatch switches between)."""
    cfg = get_arch("stablelm-1.6b").reduced()
    N, T = 3, 32
    cache = init_slot_cache(cfg, N, T, mode="int8")
    cl = jax.tree_util.tree_map(lambda a: a[0], cache)   # layer-0 slice
    rng = np.random.default_rng(5)
    Hkv, D = cfg.n_kv_heads, cfg.head_dim
    positions = jnp.asarray(rng.integers(0, 4, size=(N, 1)), jnp.int32)
    k_new = jnp.asarray(rng.normal(size=(N, 1, Hkv, D)).astype(np.float32))
    v_new = jnp.asarray(rng.normal(size=(N, 1, Hkv, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(N, cfg.n_heads, D)).astype(np.float32))

    k_full, v_full, kv_pos, _ = slot_layer_update(cl, k_new, v_new, positions)
    ref = attend(q[:, None], k_full, v_full, positions, kv_pos)[:, 0]
    new_cl = slot_layer_write(cl, k_new, v_new, positions)
    out = fused_slot_attention(new_cl, q, positions[:, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    # materialize_layer is the oracle view the fused path never builds
    km, vm = materialize_layer(new_cl)
    np.testing.assert_allclose(np.asarray(km), np.asarray(k_full), atol=0)


def test_property_random_occupancy():
    """Property sweep: random slot occupancy / head groups / chunking —
    fused (jnp path) always matches the oracle on occupied slots."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
           st.sampled_from([None, 8, 16]))
    def prop(seed, groups, kv_chunk):
        Hkv = 2
        q, k, v, kv_pos, q_pos, lens = make_case(
            seed, N=4, T=32, Hq=Hkv * groups, Hkv=Hkv, D=16, C=4)
        qk, ks, kz = quantize_kv(k, 4)
        qv, vs, vz = quantize_kv(v, 4)
        ref = reference(q, dequantize_kv(qk, ks, kz),
                        dequantize_kv(qv, vs, vz), kv_pos, q_pos)
        out = decode_attention(q, qk, qv, kv_pos, q_pos, k_scale=ks,
                               k_zero=kz, v_scale=vs, v_zero=vz,
                               mode="int8", kv_chunk=kv_chunk,
                               use_pallas=False)
        check(out, ref, lens, atol=1e-4)

    prop()


# ------------------------------------------------------- engine end-to-end ---
@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(5)]
    return cfg, model, params, prompts


def run_engine(cfg, params, prompts, *, fused, kv_mode="int8", scales=None,
               tokens=4):
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=48, max_new_tokens=tokens, prefill_bucket=8,
        kv_mode=kv_mode, fused_attn=fused), kv_scales=scales)
    for p in prompts:
        eng.submit(p)
    return [r.out for r in eng.drain()]


@pytest.mark.parametrize("kv_mode", ["fp", "int8"])
def test_engine_fused_greedy_matches_materialized(setup, kv_mode):
    """100% greedy token agreement between the fused read and the
    materialize-then-attend baseline, full generations."""
    cfg, model, params, prompts = setup
    base = run_engine(cfg, params, prompts, fused=False, kv_mode=kv_mode)
    fused = run_engine(cfg, params, prompts, fused=True, kv_mode=kv_mode)
    assert base == fused


def test_engine_fused_static_scales(setup):
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(2)]
    scales = kv_static_scales(collect_kv_stats(cfg, params, calib,
                                               qchunks=4))
    base = run_engine(cfg, params, prompts, fused=False, scales=scales)
    fused = run_engine(cfg, params, prompts, fused=True, scales=scales)
    assert base == fused


# --------------------------------------------------- mid-flight hot-swap ---
def test_hotswap_static_scales_midflight(setup):
    """Loading a recipe into a RUNNING dynamic engine: scale arrays shrink
    to per-layer constants, in-flight requests complete, and requests
    admitted after the swap decode exactly like a from-scratch static
    engine (slot attention is per-slot, so post-swap slots carry no
    dynamic-era state)."""
    from repro.calib import collect_kv_stats, kv_static_scales
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(2)]
    scales = kv_static_scales(collect_kv_stats(cfg, params, calib,
                                               qchunks=4))

    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=48, max_new_tokens=4, prefill_bucket=8,
        kv_mode="int8", fused_attn=True))
    for p in prompts[:2]:
        eng.submit(p)
    eng.step()                       # admit + decode with dynamic scales
    assert not eng.cache.static
    dyn_scale_size = eng.cache.k_scale.size
    eng.load_kv_scales(scales)       # swap WITHOUT draining the slots
    assert eng.cache.static
    assert eng.cache.k_scale.size < dyn_scale_size
    assert eng.cache.k_scale.shape[1:3] == (1, 1)
    fin = eng.drain()
    assert len(fin) == 2 and all(len(r.out) == 4 for r in fin)

    # requests admitted AFTER the swap behave as if the engine had been
    # static from the start (drain() reports cumulatively — compare only
    # the post-swap uids)
    for p in prompts[2:]:
        eng.submit(p)
    post = [r.out for r in eng.drain() if r.uid >= 2]
    fresh = run_engine(cfg, params, prompts[2:], fused=True, scales=scales)
    assert post == fresh

    with pytest.raises(ValueError, match="already serves static"):
        eng.load_kv_scales(scales)


def test_hotswap_requantizes_inflight_codes(setup):
    """The swap requantizes live codes under the new constants: a decode
    step right after the swap stays close to the fp-cache logits (static
    INT8 tolerance), i.e. the cache is still readable, not garbage."""
    from repro.calib import collect_kv_stats, kv_static_scales
    from repro.engine.kvcache import write_prefill
    from repro.models import transformer
    cfg, model, params, prompts = setup
    rng = np.random.default_rng(0)
    calib = [rng.integers(0, cfg.vocab, size=(4, 48)) for _ in range(2)]
    scales = kv_static_scales(collect_kv_stats(cfg, params, calib,
                                               qchunks=4))

    def decode_logits(cache):
        toks, pos = [], []
        for slot, p in enumerate(prompts[:2]):
            logits, pc = model.prefill(
                params, cfg, {"tokens": jnp.asarray(p)[None]})
            cache = write_prefill(cache, slot, pc, len(p))
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos.append(len(p))
        logits, _ = transformer.decode_step_slots(
            params, cfg, cache, jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32), fused=True)
        return np.asarray(logits[:, -1])

    lf = decode_logits(init_slot_cache(cfg, 2, 48, mode="fp"))
    dyn = init_slot_cache(cfg, 2, 48, mode="int8")
    # prefill into the dynamic cache, THEN swap, then decode
    toks, pos = [], []
    cache = dyn
    for slot, p in enumerate(prompts[:2]):
        logits, pc = model.prefill(params, cfg,
                                   {"tokens": jnp.asarray(p)[None]})
        cache = write_prefill(cache, slot, pc, len(p))
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos.append(len(p))
    cache = hotswap_static_scales(cache, scales)
    logits, _ = transformer.decode_step_slots(
        params, cfg, cache, jnp.asarray(toks, jnp.int32)[:, None],
        jnp.asarray(pos, jnp.int32), fused=True)
    ls = np.asarray(logits[:, -1])
    # double quantization (dynamic → static) adds at most one extra step
    # of each grid: bounded by twice the static tolerance
    assert np.max(np.abs(ls - lf)) <= 2 * 2.5 * 0.05, np.max(np.abs(ls - lf))
