"""Flight recorder, anomaly detectors, and incident bundles (DESIGN.md
§14): the always-on black box, the step-boundary detector sweep, atomic
bundle capture, and the postmortem report.

The load-bearing acceptance property: under a seeded single-fault run,
each injected fault class (exception, nan/poison corruption, crash)
yields EXACTLY ONE bundle whose trigger names the correct detector and —
when the fault is attributable — the faulted uid; a clean seeded run of
equal length yields ZERO bundles (the incident dir is never created).
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.engine import (Engine, EngineConfig, FaultInjector, FaultSpec,
                          InjectedCrash)
from repro.models import get_model
from repro.obs import (AnomalyDetector, DETECTORS, FlightRecorder,
                       atomic_dir, atomic_write_text,
                       load_incident_bundle, tail_lines,
                       write_incident_bundle)
from repro.launch.incident_report import main as report_main

KEY = jax.random.PRNGKey(0)
MAX_LEN = 48


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 14)))
               for _ in range(5)]
    return cfg, model, params, prompts


class FakeClock:
    def __init__(self, tick=0.001):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


# ====================================================== flight recorder
def test_flight_ring_drops_oldest_and_counts():
    fr = FlightRecorder(capacity=4, clock=FakeClock())
    for i in range(7):
        rec = fr.record(step=i, step_s=0.01)
        assert rec["step"] == i and "ts" in rec
    assert len(fr.records) == 4 and fr.dropped == 3
    assert [r["step"] for r in fr.window()] == [3, 4, 5, 6]
    hdr = fr.header()
    assert hdr["recorded"] == 7 and hdr["dropped"] == 3
    assert hdr["capacity"] == 4
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_tail_lines(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with open(p, "w") as f:
        for i in range(10):
            f.write(f"line{i}\n")
    assert tail_lines(p, 3) == ["line7", "line8", "line9"]
    assert tail_lines(str(tmp_path / "absent.jsonl")) == []


# ==================================================== anomaly detectors
def test_latency_spike_warmup_and_cooldown():
    det = AnomalyDetector(cooldown_steps=5, warmup_steps=3,
                          latency_factor=6.0)
    # warmup: a huge first step feeds the baseline, never fires
    assert det.sweep({"step": 0, "step_s": 5.0}) == []
    for s in range(1, 4):
        assert det.sweep({"step": s, "step_s": 0.01}) == []
    # baseline has decayed toward 0.01-ish; a 6x+ spike fires once
    fired = det.sweep({"step": 4, "step_s": 50.0})
    assert [f.detector for f in fired] == ["step_latency_spike"]
    assert fired[0].step == 4 and fired[0].value == 50.0
    # inside the cooldown window: suppressed
    assert det.sweep({"step": 5, "step_s": 500.0}) == []
    # past the cooldown: fires again
    for s in range(6, 9):
        det.sweep({"step": s, "step_s": 0.01})
    fired = det.sweep({"step": 9, "step_s": 500.0})
    assert [f.detector for f in fired] == ["step_latency_spike"]
    assert det.n_fired == 2


def test_derived_detectors_fire_on_their_signals():
    det = AnomalyDetector(cooldown_steps=100, warmup_steps=99,
                          queue_set_point=4)
    # rung ascent (0 -> 2) + queue runaway in one record
    fired = det.sweep({"step": 0, "rung": 2, "queue": 6})
    assert {f.detector for f in fired} == {"rung_ascent", "queue_runaway"}
    # rung descent never fires
    assert det.sweep({"step": 1, "rung": 0, "queue": 2}) == []
    # accept collapse: must arm (>= 2x floor) before a fall can fire
    assert det.sweep({"step": 2, "accept": 0.1}) == []     # never armed
    det.sweep({"step": 3, "accept": 0.9})                  # arms
    fired = det.sweep({"step": 4, "accept": 0.05})
    assert [f.detector for f in fired] == ["accept_collapse"]
    # clip spike: absolute threshold and jump-over-previous
    fired = det.sweep({"step": 5, "clip_frac": 0.8})
    assert [f.detector for f in fired] == ["kv_clip_spike"]


def test_clip_jump_fires_below_absolute_threshold():
    det = AnomalyDetector(cooldown_steps=1, clip_abs=0.5, clip_jump=0.25)
    assert det.sweep({"step": 0, "clip_frac": 0.05}) == []
    fired = det.sweep({"step": 1, "clip_frac": 0.4})   # +0.35 jump, < abs
    assert [f.detector for f in fired] == ["kv_clip_spike"]


def test_note_and_drain_event_detectors():
    det = AnomalyDetector(cooldown_steps=3)
    det.note("step_retry", reason="nan logits", uid=7)
    fired = det.sweep({"step": 0, "step_s": 0.01})
    assert [f.detector for f in fired] == ["step_retry"]
    assert fired[0].uid == 7 and fired[0].reason == "nan logits"
    # cooldown applies to posted events too
    det.note("step_retry", reason="again", uid=7)
    assert det.sweep({"step": 1, "step_s": 0.01}) == []
    # drain() admits out-of-step events without a record
    det.note("injected_crash", reason="boom", step=50)
    fired = det.drain()
    assert [f.detector for f in fired] == ["injected_crash"]
    with pytest.raises(ValueError, match="unknown detector"):
        det.note("gremlin")
    assert set(DETECTORS) >= {"step_retry", "injected_crash"}


# ====================================================== atomic protocol
def test_atomic_write_text_no_tmp_residue(tmp_path):
    p = str(tmp_path / "out.txt")
    atomic_write_text(p, "hello\n")
    assert open(p).read() == "hello\n"
    atomic_write_text(p, "replaced\n")
    assert open(p).read() == "replaced\n"
    assert os.listdir(tmp_path) == ["out.txt"]


def test_atomic_dir_rollback_on_exception(tmp_path):
    final = str(tmp_path / "bundle")
    with pytest.raises(RuntimeError):
        with atomic_dir(final) as tmp:
            open(os.path.join(tmp, "partial"), "w").write("x")
            raise RuntimeError("crash mid-dump")
    assert not os.path.exists(final) and not os.path.exists(final + ".tmp")
    with atomic_dir(final) as tmp:
        open(os.path.join(tmp, "f"), "w").write("ok")
    assert os.listdir(final) == ["f"]


# ===================================================== incident bundles
def _docs():
    return {
        "trigger.json": {"schema": 1, "step": 3, "trigger": {
            "detector": "step_retry", "step": 3, "reason": "nan",
            "uid": 1, "value": None}, "firings": [
            {"detector": "step_retry", "step": 3, "reason": "nan",
             "uid": 1, "value": None}]},
        "flight.json": {"header": {"schema": 1, "capacity": 8,
                                   "recorded": 4, "dropped": 0},
                        "records": [{"step": s, "ts": s * 0.1,
                                     "step_s": 0.01, "uids": [1]}
                                    for s in range(4)]},
        "metrics.json": {},
        "fingerprint.json": {"arch": "t"},
        "provenance.json": {},
        "requests.json": {"active": [], "queued": [], "poison_uids": []},
        "journal_tail.jsonl": [json.dumps({"kind": "header"})],
    }


def test_bundle_roundtrip_and_manifest(tmp_path):
    path = write_incident_bundle(str(tmp_path / "inc"),
                                 "incident-000-step_retry", _docs())
    assert os.path.basename(path) == "incident-000-step_retry"
    bundle = load_incident_bundle(path)
    assert bundle["MANIFEST.json"]["name"] == "incident-000-step_retry"
    assert bundle["trigger.json"]["trigger"]["detector"] == "step_retry"
    assert bundle["journal_tail.jsonl"] == [{"kind": "header"}]
    assert len(bundle["flight.json"]["records"]) == 4
    assert not os.path.exists(path + ".tmp")


@pytest.mark.parametrize("corrupt", [
    lambda p: os.remove(os.path.join(p, "MANIFEST.json")),
    lambda p: open(os.path.join(p, "MANIFEST.json"), "w").write("{nope"),
    lambda p: os.remove(os.path.join(p, "metrics.json")),
    lambda p: open(os.path.join(p, "flight.json"), "w").write("]["),
])
def test_load_bundle_rejects_corruption(tmp_path, corrupt):
    path = write_incident_bundle(str(tmp_path / "inc"),
                                 "incident-000-step_retry", _docs())
    corrupt(path)
    with pytest.raises(ValueError):
        load_incident_bundle(path)
    # and the CLI turns it into exit 1
    assert report_main([path, "--validate"]) == 1


def test_bundle_missing_required_file(tmp_path):
    docs = _docs()
    del docs["requests.json"]
    path = write_incident_bundle(str(tmp_path / "inc"),
                                 "incident-000-step_retry", docs)
    with pytest.raises(ValueError, match="requests.json"):
        load_incident_bundle(path)


# ============================================ engine integration (§14)
def _spy_victims(eng):
    """Ground-truth corruption victims: ``last_corrupted_uids`` resets
    every decode attempt, so accumulate it as the run proceeds."""
    victims = []
    orig = eng._faults.corrupt_tokens

    def spy(toks, active, uid_of):
        out = orig(toks, active, uid_of)
        victims.extend(u for u in eng._faults.last_corrupted_uids
                       if u not in victims)
        return out

    eng._faults.corrupt_tokens = spy
    return victims


def _chaos_engine(setup, tmp_path, fault_spec, **ecfg_kw):
    cfg, model, params, prompts = setup
    inc = str(tmp_path / "incidents")
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, prefill_bucket=8,
        fault_spec=fault_spec, incident_dir=inc, **ecfg_kw))
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    return eng, inc


def test_nan_fault_yields_one_bundle_with_victim_uid(setup, tmp_path):
    """nan corruption -> retry -> exactly one step_retry bundle naming
    the seeded victim's uid; the report validates and names the trigger."""
    spec = FaultSpec(seed=5, nan_logits_rate=1.0, max_faults=1)
    eng, inc = _chaos_engine(setup, tmp_path, spec)
    victims = _spy_victims(eng)
    eng.drain()
    assert eng.metrics()["step_retries"] == 1
    bundles = sorted(os.listdir(inc))
    assert len(bundles) == 1 and bundles[0].endswith("step_retry")
    assert eng.incidents == [os.path.join(inc, bundles[0])]
    bundle = load_incident_bundle(eng.incidents[0])
    trig = bundle["trigger.json"]["trigger"]
    assert trig["detector"] == "step_retry"
    # the spied injector victim list is the attribution oracle
    assert victims and trig["uid"] == victims[0]
    assert any(trig["uid"] in r["uids"]
               for r in bundle["flight.json"]["records"])
    assert report_main([eng.incidents[0], "--validate"]) == 0


def test_exception_fault_yields_one_bundle(setup, tmp_path):
    """A whole-step exception is unattributable (no single victim) but
    must still produce exactly one step_retry bundle."""
    spec = FaultSpec(seed=0, step_exception_rate=1.0, max_faults=1)
    eng, inc = _chaos_engine(setup, tmp_path, spec)
    eng.drain()
    bundles = sorted(os.listdir(inc))
    assert len(bundles) == 1 and bundles[0].endswith("step_retry")
    bundle = load_incident_bundle(eng.incidents[0])
    assert bundle["trigger.json"]["trigger"]["uid"] is None
    assert report_main([eng.incidents[0], "--validate"]) == 0


def test_crash_fault_dump_incident_on_supervision(setup, tmp_path):
    """InjectedCrash kills the step loop before the sweep runs, so the
    supervisor dumps from the crashed engine — the serve.py restart
    path — and the bundle's flight window describes the death."""
    spec = FaultSpec(seed=2, crash_rate=1.0, max_faults=1)
    eng, inc = _chaos_engine(setup, tmp_path, spec)
    with pytest.raises(InjectedCrash) as e:
        eng.drain()
    path = eng.dump_incident("injected_crash", reason=str(e.value))
    assert path is not None and os.path.basename(path).endswith(
        "injected_crash")
    bundle = load_incident_bundle(path)
    assert bundle["trigger.json"]["trigger"]["detector"] \
        == "injected_crash"
    assert report_main([path, "--validate"]) == 0


def test_clean_run_yields_zero_bundles(setup, tmp_path):
    """The false-positive gate: an unfaulted run of equal length writes
    nothing — the incident dir is never even created."""
    eng, inc = _chaos_engine(setup, tmp_path, None)
    fin = eng.drain()
    assert len(fin) == 5
    assert eng.incidents == [] and not os.path.exists(inc)
    assert eng.metrics()["anomalies_fired"] == 0
    assert eng.metrics()["flight_recorded"] > 0


def test_bundle_seq_survives_restart(setup, tmp_path):
    """A fresh engine (post-supervisor-restart) must not overwrite the
    previous engine's bundles: the sequence number comes from disk."""
    spec = FaultSpec(seed=5, nan_logits_rate=1.0, max_faults=1)
    eng1, inc = _chaos_engine(setup, tmp_path, spec)
    eng1.drain()
    eng2, _ = _chaos_engine(setup, tmp_path, spec)
    eng2.drain()
    names = sorted(os.listdir(inc))
    assert len(names) == 2
    assert names[0].startswith("incident-000-")
    assert names[1].startswith("incident-001-")


def test_global_cooldown_one_bundle_per_storm(setup, tmp_path):
    """poison_rate=1 faults every attempt of every request; the global
    bundle cooldown must collapse the storm into a single bundle."""
    spec = FaultSpec(seed=0, poison_rate=1.0)
    eng, inc = _chaos_engine(setup, tmp_path, spec, max_retries=1)
    eng.drain()
    assert eng.metrics()["step_retries"] > 1         # storm really raged
    assert eng.metrics()["quarantined"] == 5
    assert len(os.listdir(inc)) == 1


def test_incident_report_timeline_and_hints(setup, tmp_path, capsys):
    """The human-facing output: timeline marks the trigger step, hints
    name the root cause, --journal correlation resolves the uid."""
    journal = str(tmp_path / "j.jsonl")
    spec = FaultSpec(seed=5, nan_logits_rate=1.0, max_faults=1)
    cfg, model, params, prompts = setup
    inc = str(tmp_path / "incidents")
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, max_len=MAX_LEN, prefill_bucket=8, fault_spec=spec,
        incident_dir=inc, journal_path=journal))
    victims = _spy_victims(eng)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    eng.drain()
    [name] = os.listdir(inc)
    rc = report_main([os.path.join(inc, name), "--journal", journal])
    out = capsys.readouterr().out
    assert rc == 0
    assert "trigger step_retry" in out
    assert "timeline" in out and "root-cause hints" in out
    assert "<< step_retry" in out
    # journal correlation: the victim uid's story names its lifecycle
    assert victims and f"uid {victims[0]}" in out
