"""Quantized-model integration: quantize_tree → forward through every
family, exclusion rules, INT8 fidelity, deployed size accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.core import QuantConfig, QuantPolicy, dequantize_tree, quantize_tree
from repro.core.splitquant import SplitQuantTensor
from repro.models import get_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(key, (B, cfg.n_prefix_embeds,
                                                    1152))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("name", ASSIGNED)
def test_quantized_forward_runs(name):
    cfg = get_arch(name).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, rep = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=4)))
    assert rep["quantized"], name
    assert rep["deployed_bytes"] < rep["orig_bytes"] / 4
    logits = model.forward(qp, cfg, _batch(cfg, KEY))[0]
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["stablelm-1.6b", "rwkv6-3b",
                                  "recurrentgemma-9b", "whisper-tiny"])
def test_int8_close_to_fp32(name):
    cfg = get_arch(name).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    batch = _batch(cfg, KEY)
    ref = model.forward(params, cfg, batch)[0]
    qp, _ = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=8)))
    q = model.forward(qp, cfg, batch)[0]
    rel = np.abs(np.asarray(q) - np.asarray(ref)).max() / \
        (np.abs(np.asarray(ref)).max() + 1e-9)
    assert rel < 0.08, f"{name} INT8 rel err {rel}"


def test_exclusion_rules():
    cfg = get_arch("rwkv6-3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, rep = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=4)))
    for path in rep["quantized"]:
        assert "time_" not in path
        assert "ln_" not in path and "norm" not in path
    # decay/μ params present in skipped
    assert any("time_decay" in p for p in rep["skipped"])


def test_router_not_quantized():
    cfg = get_arch("kimi-k2-1t-a32b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, rep = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=2)))
    assert all("router" not in p for p in rep["quantized"])


def test_embeddings_optional():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    q1, r1 = quantize_tree(KEY, params, QuantPolicy(
        cfg=QuantConfig(bits=8), quantize_embeddings=False))
    q2, r2 = quantize_tree(KEY, params, QuantPolicy(
        cfg=QuantConfig(bits=8), quantize_embeddings=True))
    assert not any("embed" in p for p in r1["quantized"])
    assert any("embed" in p for p in r2["quantized"])
    # quantized-embedding model still runs
    logits = model.forward(q2, cfg, _batch(cfg, KEY))[0]
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_dequantize_tree_restores_dense():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, _ = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=8)))
    dense = dequantize_tree(qp)
    assert not any(isinstance(l, SplitQuantTensor)
                   for l in jax.tree.leaves(dense))
    batch = _batch(cfg, KEY)
    a = model.forward(qp, cfg, batch)[0]
    b = model.forward(dense, cfg, batch)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_methods_ordering_with_outliers():
    """splitquant ≤ baseline MSE on every quantized leaf at INT2 when the
    model has outlier-heavy weights (planted)."""
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    # plant outliers in attention weights
    params = jax.tree_util.tree_map_with_path(
        lambda p, x: x.at[0, 0].set(3.0)
        if (x.ndim == 2 and "attn" in jax.tree_util.keystr(p)) else x,
        params)
    pol = QuantPolicy(cfg=QuantConfig(bits=2))
    sq, _ = quantize_tree(KEY, params, pol)
    bl, _ = quantize_tree(KEY, params, pol.replace(method="baseline"))
    sq_d, bl_d = dequantize_tree(sq), dequantize_tree(bl)
    tot_sq = tot_bl = 0.0
    for ps, pb, po in zip(jax.tree.leaves(sq_d), jax.tree.leaves(bl_d),
                          jax.tree.leaves(params)):
        if ps.shape == po.shape and jnp.issubdtype(po.dtype, jnp.floating):
            tot_sq += float(jnp.sum((ps - po) ** 2))
            tot_bl += float(jnp.sum((pb - po) ** 2))
    assert tot_sq < tot_bl


def test_quantized_decode_roundtrip():
    cfg = get_arch("stablelm-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    qp, _ = quantize_tree(KEY, params, QuantPolicy(cfg=QuantConfig(bits=4)))
    toks = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    _, cache = model.prefill(qp, cfg, {"tokens": toks}, max_len=12)
    lg, cache = model.decode_step(qp, cfg, cache, toks[:, :1], jnp.int32(8))
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
